"""System behaviour: training loop convergence, checkpoint/restart
equivalence, corruption detection, straggler watchdog, serving."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.train import optimizer as O
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import TrainConfig, run_training


def small_cfg():
    return ARCHS["minitron-8b"].reduced()


def test_training_loss_decreases(tmp_path):
    cfg = small_cfg()
    shape = ShapeConfig("t", 32, 8, "train")
    mesh = make_host_mesh()
    out = run_training(cfg, shape, mesh,
                       TrainConfig(steps=40, checkpoint_every=100,
                                   checkpoint_dir=str(tmp_path / "ck"),
                                   log_every=10))
    assert out["last_loss"] < out["first_loss"] - 0.5, out


def test_checkpoint_restart_is_deterministic(tmp_path):
    """Train 20 steps; vs train 10, 'crash', resume to 20 -- the data
    pipeline is keyed by step, so the loss trajectory must agree."""
    cfg = small_cfg()
    shape = ShapeConfig("t", 32, 8, "train")
    mesh = make_host_mesh()
    # one shared schedule: the interrupted run must anneal LR identically
    oc = O.OptConfig(lr=3e-4, warmup_steps=2, total_steps=20)
    full = run_training(cfg, shape, mesh,
                        TrainConfig(steps=20, checkpoint_every=100,
                                    checkpoint_dir=str(tmp_path / "a"),
                                    log_every=1), oc)
    _ = run_training(cfg, shape, mesh,
                     TrainConfig(steps=10, checkpoint_every=10,
                                 checkpoint_dir=str(tmp_path / "b"),
                                 log_every=1), oc)
    resumed = run_training(cfg, shape, mesh,
                           TrainConfig(steps=20, checkpoint_every=10,
                                       checkpoint_dir=str(tmp_path / "b"),
                                       log_every=1), oc)
    want = [r["loss"] for r in full["log"] if r["step"] >= 10]
    got = [r["loss"] for r in resumed["log"] if r["step"] >= 10]
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_checkpoint_corruption_detected(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(64, dtype=jnp.float32)}
    cm.save(5, tree, blocking=True)
    path = tmp_path / "step_00000005"
    fn = [f for f in os.listdir(path) if f.endswith(".npy")][0]
    arr = np.load(path / fn)
    arr[0] += 1
    np.save(path / fn, arr)
    with pytest.raises(OSError, match="checksum"):
        cm.restore(5, tree)


def test_checkpoint_gc_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros(8)}
    for s in (1, 2, 3, 4):
        cm.save(s, tree, blocking=True)
    assert cm.all_steps() == [3, 4]
    assert cm.latest_step() == 4


def test_checkpoint_torn_write_ignored(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.zeros(8)}
    cm.save(7, tree, blocking=True)
    # a crashed writer leaves a .tmp dir: must not be listed
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert cm.all_steps() == [7]


def test_straggler_watchdog_flags_injected_delay(tmp_path):
    cfg = small_cfg()
    shape = ShapeConfig("t", 32, 4, "train")
    out = run_training(cfg, shape, make_host_mesh(),
                       TrainConfig(steps=16, checkpoint_every=100,
                                   checkpoint_dir=str(tmp_path / "ck")),
                       inject_delay_at=12)
    assert any(e["step"] == 12 for e in out["straggler_events"]), \
        out["straggler_events"]


def test_elastic_restore_new_topology(tmp_path):
    """Checkpoints hold unsharded logical arrays -> restoring onto a
    different sharding layout must be exact (elastic rescale path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    cm.save(1, tree, blocking=True)
    mesh = make_host_mesh()   # 1 device; layout changes, math must not
    sh = {"w": NamedSharding(mesh, P(None, "model"))}
    out = cm.restore(1, tree, sh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


def test_clutch_sampler_equals_jnp_sampler():
    from repro.serve.engine import SamplerConfig, sample

    cfg = small_cfg()
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 512)).astype(np.float32) * 5)
    key = jax.random.PRNGKey(0)
    a = sample(cfg, logits, key, SamplerConfig(use_clutch_mask=True))
    b = sample(cfg, logits, key, SamplerConfig(use_clutch_mask=False))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_optimizer_schedule():
    oc = O.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(O.schedule(oc, jnp.int32(0))) < 0.2
    assert abs(float(O.schedule(oc, jnp.int32(10))) - 1.0) < 0.1
    assert float(O.schedule(oc, jnp.int32(99))) < 0.01
