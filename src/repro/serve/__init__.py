"""repro.serve: the serving layer.

* :mod:`repro.serve.engine` -- continuous-batching LM serving with the
  Clutch threshold sampler (JAX).
* :mod:`repro.serve.pud_service` -- the request/response front end over
  :class:`repro.pud.PudSession`: batched PuD query/inference requests
  with per-request results and barrier-aware stats (NumPy only).

Submodules are imported explicitly (``engine`` pulls in JAX; the PuD
service does not).
"""
