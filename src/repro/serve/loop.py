"""Simulated-clock open-loop serving for the PuD substrate.

Serving model (the loop)
------------------------
:class:`ServingLoop` is the piece that turns nanosecond-accurate
scheduler makespans into *serving* metrics -- p50/p99 latency and
goodput under offered load.  One simulated clock drives everything:

1. **Ingest** -- open-loop arrivals (see :mod:`repro.serve.arrivals`)
   are offered to the :class:`~repro.serve.admission.\
AdmissionController` the moment the clock passes their timestamps;
   overload sheds come back as explicit 429 responses and are recorded
   as served (failed) requests, never silently dropped.
2. **Form** -- when the server is free, up to ``max_batch`` requests
   leave admission (weighted priority, starvation-bounded).  Each
   taken request's *remaining* deadline budget is its absolute
   deadline minus the clock: queueing delay eats SLO, exactly like a
   real server.  A request whose budget is already negative is shed
   here (it could never succeed; scheduling it would be the PL401
   pudlint violation) -- dispatched requests are reported to the
   pudlint collector so the serving-admission pass audits every
   schedule this loop commits.
3. **Execute** -- the batch dispatches through the
   :class:`~repro.serve.batcher.DeadlineBatcher` (probe, predict,
   split); the clock advances by the committed sub-batches' serial
   makespan, so service time feeds back into queueing delay for
   everything still waiting -- saturation emerges instead of being
   modeled.
4. **Scale** -- each committed job's timeline feeds the optional
   :class:`~repro.serve.autoscaler.UtilizationAutoscaler`, whose
   config changes take effect on the next dispatch.  The dispatched
   resource's raw command trace is then retired
   (:meth:`~repro.pud.session.PudSession.clear_traces`): job-scoped
   stats, lint and attribution all happen before retirement, and a
   long-running server must not accumulate trace history without
   bound.

The returned :class:`ServingReport` carries every per-request record
plus the derived curve points (p50/p99 over *successful* requests,
goodput = deadline-met completions per simulated second).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

from repro.core import machine

from .admission import AdmissionController
from .arrivals import Arrival
from .batcher import DeadlineBatcher
from .pud_service import PudService


@dataclass(frozen=True)
class ServedRecord:
    """One request's life on the simulated clock.  ``start_ns`` /
    ``finish_ns`` are ``None`` for requests shed before execution;
    ``latency_ns`` (arrival -> finish, queueing included) is ``None``
    unless the request actually executed."""

    rid: int
    cls: str
    arrive_ns: float
    ok: bool
    error: str | None = None
    start_ns: float | None = None
    finish_ns: float | None = None

    @property
    def latency_ns(self) -> float | None:
        if self.finish_ns is None:
            return None
        return self.finish_ns - self.arrive_ns


@dataclass
class ServingReport:
    """All records of one :meth:`ServingLoop.run`, plus derived serving
    metrics.  ``goodput_rps`` counts only ``ok`` completions (SLO met,
    not shed) per simulated second -- the quantity that saturates and
    then *degrades* as offered load outruns capacity."""

    records: list[ServedRecord] = field(default_factory=list)
    duration_ns: float = 0.0
    splits: int = 0
    probes: int = 0
    decisions: list = field(default_factory=list)

    @property
    def offered(self) -> int:
        return len(self.records)

    @property
    def completed(self) -> int:
        return sum(1 for r in self.records if r.ok)

    @property
    def shed(self) -> int:
        return sum(1 for r in self.records if r.start_ns is None)

    def latencies_ns(self) -> list[float]:
        return sorted(r.latency_ns for r in self.records
                      if r.ok and r.latency_ns is not None)

    def percentile_ns(self, p: float) -> float:
        lats = self.latencies_ns()
        if not lats:
            return float("nan")
        return float(np.percentile(lats, p))

    @property
    def p50_ns(self) -> float:
        return self.percentile_ns(50.0)

    @property
    def p99_ns(self) -> float:
        return self.percentile_ns(99.0)

    @property
    def goodput_rps(self) -> float:
        if self.duration_ns <= 0:
            return 0.0
        return self.completed / (self.duration_ns / 1e9)

    def to_json(self) -> dict:
        return {
            "offered": self.offered, "completed": self.completed,
            "shed": self.shed, "splits": self.splits,
            "probes": self.probes,
            "duration_ns": self.duration_ns,
            "p50_ns": self.p50_ns, "p99_ns": self.p99_ns,
            "goodput_rps": self.goodput_rps,
        }


class ServingLoop:
    """Event loop binding arrivals -> admission -> batcher -> scaler
    over one :class:`~repro.serve.pud_service.PudService`."""

    def __init__(self, service: PudService,
                 admission: AdmissionController,
                 batcher: DeadlineBatcher | None = None,
                 autoscaler=None, max_batch: int = 8) -> None:
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.service = service
        self.admission = admission
        self.batcher = batcher or DeadlineBatcher(service)
        self.autoscaler = autoscaler
        self.max_batch = max_batch

    def run(self, arrivals: list[Arrival]) -> ServingReport:
        """Serve every arrival to completion on the simulated clock and
        return the full report (records in completion order)."""
        arrivals = sorted(arrivals, key=lambda a: a.arrive_ns)
        report = ServingReport()
        clock = 0.0
        i = 0
        while i < len(arrivals) or self.admission.depth:
            if self.admission.depth == 0:
                clock = max(clock, arrivals[i].arrive_ns)
            while i < len(arrivals) and arrivals[i].arrive_ns <= clock:
                shed = self.admission.offer(arrivals[i])
                if shed is not None:
                    report.records.append(ServedRecord(
                        rid=arrivals[i].rid, cls=arrivals[i].cls,
                        arrive_ns=arrivals[i].arrive_ns,
                        ok=False, error=shed.error))
                i += 1
            if self.admission.depth == 0:
                continue
            clock = self._dispatch(
                self.admission.take(self.max_batch), clock, report)
        report.duration_ns = clock
        report.splits = self.batcher.splits
        report.probes = self.batcher.probes
        if self.autoscaler is not None:
            report.decisions = list(self.autoscaler.decisions)
        return report

    # ------------------------------------------------------------------ #
    def _dispatch(self, taken: list[Arrival], now: float,
                  report: ServingReport) -> float:
        """Execute one admission draw: shed already-expired requests,
        group the rest per (resource, kind) like ``PudService.flush``,
        and run each group serially through the batcher.  Returns the
        new clock."""
        by_rid: dict[int, Arrival] = {}
        groups: dict[tuple[str, str], list] = {}
        for a in taken:
            deadline_abs = a.deadline_abs_ns
            if deadline_abs is not None and deadline_abs < now:
                # dispatching this would BE the PL401 violation: shed
                # it with an explicit overload-class error instead
                report.records.append(ServedRecord(
                    rid=a.rid, cls=a.cls, arrive_ns=a.arrive_ns,
                    ok=False, error=(
                        f"429 overloaded: deadline "
                        f"{deadline_abs:.0f} ns expired before batch "
                        f"start {now:.0f} ns; request shed unexecuted")))
                continue
            by_rid[a.rid] = a
            req = a.request
            kind = "query" if req.query is not None else "predict"
            groups.setdefault((req.resource_name, kind), []).append(a)
        offset = 0.0
        for (name, kind), group in groups.items():
            handle = self.service._handle(name, kind)
            start = now + offset
            reqs = []
            for a in group:
                deadline_abs = a.deadline_abs_ns
                self._audit(a, start, deadline_abs)
                budget = None if deadline_abs is None \
                    else deadline_abs - start
                reqs.append(replace(a.request, deadline_ns=budget))
            outcome = self.batcher.dispatch(handle, kind, reqs)
            for a, resp in zip(group, outcome.responses):
                report.records.append(ServedRecord(
                    rid=a.rid, cls=a.cls, arrive_ns=a.arrive_ns,
                    ok=resp.ok, error=resp.error, start_ns=start,
                    finish_ns=start + resp.latency_ns))
            if self.autoscaler is not None:
                ex = self.service.session.executor(handle)
                for job in outcome.jobs:
                    self.autoscaler.observe(ex, job.timeline)
            # retire the resource's raw command trace now that the
            # dispatch is committed, linted (per-job verify + PL4xx
            # audit) and observed: a long-running server would
            # otherwise grow every subarray's recorded history without
            # bound, and whole-trace lints would see successive jobs'
            # row reuse as cross-job hazards no scheduler ever races
            self.service.session.clear_traces(handle)
            offset += outcome.makespan_ns
        return now + offset

    @staticmethod
    def _audit(a: Arrival, start_ns: float,
               deadline_abs: float | None) -> None:
        """Report one dispatched request to the active pudlint
        collector (``machine._LINT_REGISTRY``) for the PL4xx
        serving-admission pass."""
        reg = machine._LINT_REGISTRY
        if reg is not None and hasattr(reg, "add_serving"):
            reg.add_serving({"rid": a.rid, "cls": a.cls,
                             "start_ns": start_ns,
                             "deadline_ns": deadline_abs})
