"""PuD device hierarchy: channels x ranks x banks owning bank placement
and command-stream scheduling.

The machine layer (:mod:`repro.core.machine`) models *one bank group* --
a set of banks executing a broadcast command stream.  This module adds the
device above it:

  * :class:`PuDDevice` mirrors a :class:`~repro.core.cost.SystemConfig`'s
    channel/rank/bank topology and hands out :class:`BankGroup` slices of
    it.  Banks are addressed ``(channel, rank, bank)`` in row-major order
    over the flat index space.
  * **Channel-aware placement**: ``alloc_banks`` takes a ``channels``
    argument -- ``None`` (first-fit contiguous, the bump-pointer
    behavior), a channel index (place the whole group inside that
    channel), an explicit list of channels, or ``"spread"`` (balance the
    group's banks round-robin over every channel).  Apps use this to put
    independent shards on disjoint command buses so their streams
    overlap, or co-resident on one bus when capacity matters more than
    latency.
  * **Execution model**: engines *record* typed command streams while
    they run (each group's :class:`~repro.core.machine.CommandTrace`,
    with dependency segments and host-barrier events); :meth:`schedule`
    hands every placed group's stream + physical footprint to the
    per-channel command-bus scheduler (:mod:`repro.core.scheduler`) and
    returns the scheduled :class:`~repro.core.scheduler.Timeline`,
    host spans included (placed across the system's ``host_lanes``
    concurrent merge lanes).  :meth:`cost_summary` derives device
    latency/energy from that timeline (``cost.timeline_cost``) and
    keeps the old serialized-sum / perfect-overlap numbers as the
    bracketing bounds the scheduler must land between.
  * **Dynamic bank reuse**: :meth:`free_banks` releases a placed
    group's banks back to the free map and prunes it from
    placement/streams, so serving workloads can rotate tables/forests
    on one device instead of rebuilding it.  The free map is an
    explicit sorted range list: freeing coalesces adjacent ranges, so
    alloc -> free -> realloc of a *larger* contiguous group succeeds
    whenever a hole of that size exists (``free_ranges`` /
    ``largest_free_run`` expose the map for placement planners).
  * **Defragmentation**: :meth:`defragment` compacts placed groups
    toward the start of each channel, closing the holes that remain
    when interleaved lifetimes fragment the free map.  Group state
    lives in each group's :class:`~repro.core.machine.BankedSubarray`
    (indexed by group, not by physical bank), so relocation preserves
    LUT/vector contents bit-exactly; the physical cost of moving a
    group is recorded in its command stream as RowClone relocation
    waves (MRACT-chunked under the PULSAR ``multi_row_act``
    capability) -- pure in-DRAM movement with zero host bytes -- or,
    with ``rowclone=False``, as the legacy host READ/WRITE round trip
    per occupied row (the measured baseline).  Runs never leave their
    channel, so channel footprints (and therefore which groups can
    overlap on the bus) are unchanged.
"""

from __future__ import annotations

import bisect

from dataclasses import dataclass

import numpy as np

from .machine import BankedSubarray, PuDArch, PuDOp
from .scheduler import ChannelScheduler, Footprint, GroupStream, Timeline


@dataclass(frozen=True)
class BankAddress:
    channel: int
    rank: int
    bank: int


@dataclass
class BankGroup:
    """A placed engine: which flat banks it owns and its machine state.
    ``active_elems`` is the SIMD width the engine actually uses (real
    records/nodes, not padded columns); ``None`` means all columns."""

    banks: tuple[int, ...]
    sub: BankedSubarray
    label: str = ""
    active_elems: int | None = None

    @property
    def first_bank(self) -> int:
        return self.banks[0]

    @property
    def num_banks(self) -> int:
        return self.sub.num_banks


class PuDDevice:
    """A whole PuD-enabled memory device (channels x ranks x banks)."""

    def __init__(
        self,
        arch: PuDArch,
        channels: int = 2,
        ranks_per_channel: int = 2,
        banks_per_rank: int = 16,
        num_rows: int = 1024,
        cols_per_bank: int = 65536,
        seed: int | None = 0,
        multi_row_act: int = 1,
    ) -> None:
        self.arch = arch
        self.channels = channels
        self.ranks_per_channel = ranks_per_channel
        self.banks_per_rank = banks_per_rank
        self.num_rows = num_rows
        self.cols_per_bank = cols_per_bank
        self._seed = seed
        #: PULSAR multi-row-ACT span capability, threaded into every
        #: allocated group's :class:`BankedSubarray` (1 = off).
        self.multi_row_act = multi_row_act
        # Free map: sorted, non-overlapping, non-adjacent [start, length]
        # ranges (adjacent ranges are always coalesced on free).
        self._ranges: list[list[int]] = [[0, self.total_banks]]
        self.groups: list[BankGroup] = []

    @classmethod
    def from_system(cls, sys_cfg, arch: PuDArch,
                    num_rows: int = 1024) -> "PuDDevice":
        """Build a device matching a cost-model SystemConfig topology."""
        return cls(arch, channels=sys_cfg.channels,
                   ranks_per_channel=sys_cfg.ranks_per_channel,
                   banks_per_rank=sys_cfg.banks_per_rank,
                   num_rows=num_rows, cols_per_bank=sys_cfg.cols_per_bank,
                   multi_row_act=sys_cfg.multi_row_act)

    # ------------------------------------------------------------------ #
    @property
    def total_banks(self) -> int:
        return self.channels * self.ranks_per_channel * self.banks_per_rank

    @property
    def banks_free(self) -> int:
        return sum(length for _, length in self._ranges)

    @property
    def free_ranges(self) -> tuple[tuple[int, int], ...]:
        """The free map as sorted, coalesced ``(start, length)`` ranges."""
        return tuple((s, length) for s, length in self._ranges)

    @property
    def largest_free_run(self) -> int:
        """Largest contiguous allocatable run (0 when the device is
        full).  ``banks_free > largest_free_run`` means the free space
        is fragmented -- a :meth:`defragment` candidate."""
        return max((length for _, length in self._ranges), default=0)

    @property
    def parallel_cols(self) -> int:
        """Device SIMD width when every bank computes."""
        return self.total_banks * self.cols_per_bank

    @property
    def banks_per_channel(self) -> int:
        return self.ranks_per_channel * self.banks_per_rank

    def address(self, flat_bank: int) -> BankAddress:
        """(channel, rank, bank) of a flat bank index."""
        if not 0 <= flat_bank < self.total_banks:
            raise IndexError(flat_bank)
        per_ch = self.banks_per_channel
        return BankAddress(
            channel=flat_bank // per_ch,
            rank=(flat_bank % per_ch) // self.banks_per_rank,
            bank=flat_bank % self.banks_per_rank,
        )

    # ------------------------------------------------------------------ #
    # Placement
    # ------------------------------------------------------------------ #
    def _find_contiguous(self, n: int, lo: int, hi: int) -> list[int]:
        """First-fit run of ``n`` free banks inside [lo, hi); [] if none.
        Pure lookup -- the caller carves the run once the whole
        placement has resolved, so a multi-channel request that fails
        on a later channel leaks nothing."""
        for start, length in self._ranges:
            a, b = max(start, lo), min(start + length, hi)
            if b - a >= n:
                return list(range(a, a + n))
        return []

    def _carve(self, start: int, n: int) -> None:
        """Remove the run [start, start+n) from the free map (the run
        must lie inside one free range)."""
        for i, (s, length) in enumerate(self._ranges):
            if s <= start and start + n <= s + length:
                pieces = []
                if start > s:
                    pieces.append([s, start - s])
                if s + length > start + n:
                    pieces.append([start + n, s + length - (start + n)])
                self._ranges[i:i + 1] = pieces
                return
        raise AssertionError(
            f"carve of [{start}, {start + n}) misses the free map")

    def _insert_free(self, start: int, n: int) -> None:
        """Return the run [start, start+n) to the free map, coalescing
        with adjacent free ranges so fragmentation never accumulates
        from the free path itself."""
        i = bisect.bisect([s for s, _ in self._ranges], start)
        self._ranges.insert(i, [start, n])
        if i + 1 < len(self._ranges) and \
                start + n == self._ranges[i + 1][0]:
            self._ranges[i][1] += self._ranges[i + 1][1]
            del self._ranges[i + 1]
        if i > 0 and \
                self._ranges[i - 1][0] + self._ranges[i - 1][1] == start:
            self._ranges[i - 1][1] += self._ranges[i][1]
            del self._ranges[i]

    def _channel_free(self, c: int) -> int:
        per_ch = self.banks_per_channel
        lo, hi = c * per_ch, (c + 1) * per_ch
        return sum(max(0, min(s + length, hi) - max(s, lo))
                   for s, length in self._ranges)

    def _resolve_placement(self, n: int, channels) -> list[int]:
        per_ch = self.banks_per_channel
        if channels is None:
            picked = self._find_contiguous(n, 0, self.total_banks)
            if picked:
                return picked
            raise MemoryError(
                f"device bank budget exceeded: no contiguous run of {n} "
                f"banks free ({self.banks_free}/{self.total_banks} free)")
        if isinstance(channels, (int, np.integer)):
            channels = [int(channels)]
        if channels == "spread":
            channels = list(range(self.channels))
        channels = list(dict.fromkeys(channels))  # dedupe, keep order
        if any(not 0 <= c < self.channels for c in channels):
            raise IndexError(f"channel out of range: {channels}")
        # Balanced split over the requested channels, preferring emptier
        # ones for the remainder banks.
        base, rem = divmod(n, len(channels))
        order = sorted(channels, key=lambda c: -self._channel_free(c))
        want = {c: base for c in channels}
        for c in order[:rem]:
            want[c] += 1
        picked: list[int] = []
        for c in channels:
            if want[c] == 0:
                continue
            got = self._find_contiguous(want[c], c * per_ch,
                                        (c + 1) * per_ch)
            if not got:
                raise MemoryError(
                    f"channel {c} cannot place {want[c]} contiguous banks "
                    f"({self._channel_free(c)} free)")
            picked.extend(got)
        return picked

    @staticmethod
    def _runs(banks) -> list[tuple[int, int]]:
        """Maximal consecutive (start, length) runs of a bank set."""
        out: list[tuple[int, int]] = []
        for b in sorted(banks):
            if out and out[-1][0] + out[-1][1] == b:
                out[-1] = (out[-1][0], out[-1][1] + 1)
            else:
                out.append((b, 1))
        return out

    def alloc_banks(self, n: int, num_cols: int | None = None,
                    label: str = "", channels=None,
                    active_elems: int | None = None) -> BankedSubarray:
        """Allocate ``n`` banks as one broadcast group and return its
        machine state.  ``channels`` selects the placement policy (see
        module docstring); ``active_elems`` records how many SIMD lanes
        the engine will actually use (throughput accounting excludes
        padded columns).  Raises MemoryError when the requested
        placement does not fit (callers shard or queue waves above this
        layer)."""
        if n < 1:
            raise ValueError("need at least one bank")
        banks = self._resolve_placement(n, channels)
        sub = BankedSubarray(
            num_banks=n, num_rows=self.num_rows,
            num_cols=num_cols or self.cols_per_bank, arch=self.arch,
            seed=None if self._seed is None
            else self._seed + banks[0],
            multi_row_act=self.multi_row_act)
        group = BankGroup(banks=tuple(banks), sub=sub, label=label,
                          active_elems=active_elems)
        for start, length in self._runs(banks):
            self._carve(start, length)
        self.groups.append(group)
        return sub

    def free_banks(self, group: "BankGroup | BankedSubarray") -> None:
        """Release a placed group's banks back to the free map and prune
        it from placement/streams, so long-running serving can rotate
        tables/forests without building a new device.  Accepts the
        :class:`BankGroup` or the :class:`BankedSubarray` that
        ``alloc_banks`` returned.  The group's recorded stream stops
        being scheduled; its banks become allocatable immediately."""
        if isinstance(group, BankedSubarray):
            matches = [g for g in self.groups if g.sub is group]
        else:
            matches = [g for g in self.groups if g is group]
        if not matches:
            raise ValueError("group is not placed on this device")
        g = matches[0]
        for start, length in self._runs(g.banks):
            self._insert_free(start, length)
        self.groups.remove(g)

    # ------------------------------------------------------------------ #
    # Defragmentation
    # ------------------------------------------------------------------ #
    def defragment(self, rowclone: bool = True) -> int:
        """Compact placed groups toward the start of each channel,
        coalescing every channel's free space into one tail run.

        Each group's per-channel bank runs slide down (placement order
        preserved) without crossing channel boundaries, so the group's
        channel footprint -- which buses it occupies, hence which
        groups it serializes with -- is unchanged.  Group *state* is
        untouched (it lives in the group's own
        :class:`~repro.core.machine.BankedSubarray`); the physical move
        is recorded in each relocated group's command stream in a
        dedicated ``defrag`` segment that subsequent (default-chained)
        segments depend on.  By default (``rowclone=True``) relocation
        is pure in-DRAM movement: one RowClone wave per occupied row
        (chunked into MRACT spans when the device has the PULSAR
        ``multi_row_act`` capability) -- no host lane, no off-chip
        bytes.  ``rowclone=False`` keeps the legacy host path (one READ
        + one WRITE per occupied row over the channel), the baseline
        the in-DRAM path is measured against.  Returns the number of
        banks moved.
        """
        per_ch = self.banks_per_channel
        new_banks = {id(g): list(g.banks) for g in self.groups}
        moved_groups: set[int] = set()
        moved = 0
        for c in range(self.channels):
            lo = c * per_ch
            items: list[tuple[int, list[int], BankGroup]] = []
            for g in self.groups:
                for start, length in self._runs(
                        b for b in g.banks if lo <= b < lo + per_ch):
                    items.append((start, list(range(start, start + length)),
                                  g))
            items.sort(key=lambda it: it[0])
            cursor = lo
            for start, run, g in items:
                if start != cursor:
                    remap = {old: cursor + k for k, old in enumerate(run)}
                    nb = new_banks[id(g)]
                    for j, b in enumerate(nb):
                        if b in remap:
                            nb[j] = remap[b]
                    moved += len(run)
                    moved_groups.add(id(g))
                cursor += len(run)
        for g in self.groups:
            if id(g) in moved_groups:
                g.banks = tuple(new_banks[id(g)])
                tr = g.sub.trace
                rows = max(1, g.sub._alloc_ptr)
                tr.begin_segment(f"defrag:{g.label or 'group'}")
                if rowclone:
                    # In-DRAM relocation: one clone wave per occupied
                    # row (MRACT-chunked), row indices unchanged.
                    g.sub.rowclone_rows(0, 0, rows)
                else:
                    # Legacy host baseline: round trip every row.
                    tr.emit_rows(PuDOp.READ, 0, rows)
                    tr.emit_rows(PuDOp.WRITE, 0, rows)
        used = sorted(b for g in self.groups for b in g.banks)
        self._ranges = []
        prev = 0
        for start, length in self._runs(used):
            if start > prev:
                self._ranges.append([prev, start - prev])
            prev = start + length
        if prev < self.total_banks:
            self._ranges.append([prev, self.total_banks - prev])
        return moved

    def footprint(self, group: BankGroup) -> Footprint:
        """{channel: {rank: bank count}} of a group's placement."""
        out: Footprint = {}
        for b in group.banks:
            a = self.address(b)
            out.setdefault(a.channel, {}).setdefault(a.rank, 0)
            out[a.channel][a.rank] += 1
        return out

    # ------------------------------------------------------------------ #
    # Scheduling + cost
    # ------------------------------------------------------------------ #
    def _group_label(self, i: int, g: BankGroup) -> str:
        base = g.label or "group"
        return f"{base}@{g.first_bank}" if any(
            j != i and (h.label or "group") == base
            for j, h in enumerate(self.groups)) else base

    def streams(self) -> list[GroupStream]:
        """Every placed group's recorded stream (waves + host events) +
        physical footprint + active SIMD width."""
        return [
            GroupStream.from_trace(self._group_label(i, g), g.sub.trace,
                                   self.footprint(g), g.sub.num_cols,
                                   active_elems=g.active_elems,
                                   machine=g.sub)
            for i, g in enumerate(self.groups)
        ]

    def schedule(self, sys_cfg) -> Timeline:
        """Run every group's recorded stream through the per-channel
        command-bus scheduler -> scheduled device timeline."""
        return ChannelScheduler(sys_cfg).schedule(self.streams())

    def cost_summary(self, sys_cfg) -> dict:
        """Device-level latency/energy from the scheduled timeline.

        ``time_scheduled_ns`` is the makespan of the per-channel bus
        schedule, host-lane spans included -- the primary number
        (``time_device_ns`` is the DRAM-only span).  ``time_serial_ns``
        (all groups back-to-back on one bus plus all host work) and
        ``time_overlap_ns`` (perfect overlap) remain as the bracketing
        bounds; per-group entries keep the standalone histogram cost
        (``cost.trace_cost``), with host I/O charged at the channel
        share the group actually spans so the histogram and timeline
        paths agree on bandwidth accounting.
        """
        from . import cost

        timeline = self.schedule(sys_cfg)
        kc = cost.timeline_cost(timeline, sys_cfg)
        per_group = []
        for i, g in enumerate(self.groups):
            label = self._group_label(i, g)
            tc = cost.trace_cost(g.sub.trace.counts(), sys_cfg,
                                 banks=g.num_banks,
                                 cols_per_bank=g.sub.num_cols,
                                 channels=len(self.footprint(g)),
                                 elems=g.active_elems)
            span = timeline.group_span_ns.get(label)
            per_group.append({
                "label": label,
                "banks": g.num_banks,
                "channels": sorted(self.footprint(g)),
                "pud_ops": g.sub.trace.pud_ops,
                "time_ns": tc.time_ns,
                "sched_busy_ns": timeline.group_busy_ns.get(label, 0.0),
                "sched_span_ns": span,
                "energy_nj": tc.energy_nj,
            })
        return {
            "groups": per_group,
            "banks_used": self.total_banks - self.banks_free,
            "time_scheduled_ns": timeline.makespan_ns,
            "time_device_ns": timeline.device_span_ns,
            "time_serial_ns": timeline.serial_bound_ns,
            "time_overlap_ns": timeline.overlap_bound_ns,
            "channel_busy_ns": timeline.channel_busy_ns,
            "host_busy_ns": timeline.host_busy_ns,
            "host_lane_busy_ns": timeline.host_lane_busy_ns,
            "host_utilization": timeline.host_utilization,
            "energy_nj": sum(g["energy_nj"] for g in per_group),
            "energy_scheduled_nj": kc.energy_nj,
        }
