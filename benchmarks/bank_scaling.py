"""Throughput vs bank count from REAL banked-machine traces.

Unlike ``paper_figs`` (closed-form op histograms), these rows run the
functional banked engines, capture their actual command traces, and feed
them through the BLP cost model (``cost.trace_cost``) at each bank count
-- the measurement path the multi-bank refactor enables.  Reported:

  * GBDT: one batch (one instance per bank) per wave; derived column is
    instances/ms of modeled DRAM time.
  * Predicate Q2: a table sharded across ``banks``; derived column is
    Giga-records/s of modeled DRAM time.
  * functional-simulator wall-clock per broadcast wave (NumPy time, not
    DRAM time) to show the simulator itself scales with vectorization.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

import numpy as np

from repro.apps import gbdt as G
from repro.apps import predicate as P
from repro.core import cost
from repro.core.machine import PuDArch

BANK_SWEEP = (1, 4, 16, 64)


def _channels_spanned(banks: int, sys_cfg: cost.SystemConfig) -> int:
    """Channels a contiguous ``banks``-bank placement would span --
    charge host I/O at that share, matching the bus scheduler."""
    per_ch = sys_cfg.ranks_per_channel * sys_cfg.banks_per_rank
    return min(sys_cfg.channels, -(-banks // per_ch))


def gbdt_bank_scaling(smoke: bool = False):
    rows = []
    trees, feats = (8, 3) if smoke else (64, 8)
    forest = G.ObliviousForest.random(num_trees=trees, depth=4 if smoke
                                      else 6, num_features=feats,
                                      n_bits=8, seed=0)
    rng = np.random.default_rng(1)
    for banks in BANK_SWEEP[:2] if smoke else BANK_SWEEP:
        eng = G.GbdtPudEngine(forest, PuDArch.MODIFIED, num_banks=banks)
        x = rng.integers(0, 256, (banks, feats), dtype=np.uint64)
        eng.sub.trace.clear()
        t0 = time.perf_counter()
        eng.infer(x)
        wall_us = (time.perf_counter() - t0) * 1e6
        kc = cost.trace_cost(eng.sub.trace.counts(), cost.DESKTOP,
                             banks=banks, cols_per_bank=eng.sub.num_cols,
                             channels=_channels_spanned(banks, cost.DESKTOP))
        inst_per_ms = banks / (kc.time_ns / 1e6)
        rows.append((f"bank_scaling_gbdt_b{banks}",
                     round(kc.time_ns / 1e3, 2), round(inst_per_ms, 1)))
        rows.append((f"bank_scaling_gbdt_b{banks}_sim_wallclock",
                     round(wall_us, 1), banks))
    return rows


def predicate_bank_scaling(smoke: bool = False):
    rows = []
    for banks in (1, 2) if smoke else (1, 4, 16):
        n = banks * 4096
        t = P.Table.generate(n, 8, seed=3)
        e = P.PudQueryEngine(t, PuDArch.MODIFIED, "clutch",
                             cols_per_bank=4096)
        e.sub.trace.clear()
        mx = 255
        e.q2(fi=0, x0=mx // 8, x1=mx // 2, fj=1, y0=mx // 4,
             y1=3 * mx // 4)
        kc = cost.trace_cost(e.sub.trace.counts(), cost.DESKTOP,
                             banks=banks, cols_per_bank=e.sub.num_cols,
                             channels=_channels_spanned(banks, cost.DESKTOP))
        grps = n / kc.time_ns  # records per ns == G-records/s
        rows.append((f"bank_scaling_q2_b{banks}",
                     round(kc.time_ns / 1e3, 2), round(grps, 3)))
    return rows


def run(smoke: bool = False):
    return gbdt_bank_scaling(smoke) + predicate_bank_scaling(smoke)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configs for CI regression smoke")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(smoke=args.smoke):
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
