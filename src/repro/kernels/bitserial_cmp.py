"""Pallas TPU kernel: bit-serial borrow-chain comparison (the baseline).

Computes ``a < B`` over binary bit-planes with the MAJ3 borrow recurrence
(unrolled over the static bit-width).  Exists so the TPU-side benchmark can
compare Clutch's O(C) merge against the O(n) baseline on identical layouts,
mirroring the paper's Fig. 10 kernel comparison.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import SUBLANES, maj3, use_interpret


def _kernel(nota_ref, planes_ref, out_ref, *, n_bits: int):
    borrow = jnp.zeros_like(out_ref[...])
    for i in range(n_bits):
        not_a = nota_ref[i]                       # 0x0 or 0xFFFFFFFF
        plane = pl.load(planes_ref, (pl.ds(i, 1), slice(None)))[0]
        borrow = maj3(jnp.broadcast_to(not_a, borrow.shape), plane, borrow)
    out_ref[...] = borrow


def bitserial_cmp(planes: jnp.ndarray, not_a_words: jnp.ndarray,
                  block_words: int = 2048) -> jnp.ndarray:
    """planes: [n_pad, W] uint32 (LSB first, n_pad % 8 == 0);
    not_a_words: [n_bits] uint32 with 0xFFFFFFFF where the scalar bit is 0.
    Returns [W] uint32 bitmap of ``a < B``."""
    n_pad, w = planes.shape
    n_bits = not_a_words.shape[0]
    assert n_pad % SUBLANES == 0 and w % 128 == 0
    from .common import choose_block
    bw = choose_block(w, min(block_words, w))
    kernel = functools.partial(_kernel, n_bits=n_bits)
    return pl.pallas_call(
        kernel,
        grid=(w // bw,),
        in_specs=[
            pl.BlockSpec((n_bits,), lambda i: (0,)),
            pl.BlockSpec((n_pad, bw), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((bw,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.uint32),
        interpret=use_interpret(),
    )(not_a_words, planes)
