"""Application-level tests: predicate evaluation Q1-Q5 and GBDT inference
against exact NumPy references, on both PuD architectures and both
methods (Clutch + bit-serial baseline)."""

import numpy as np
import pytest

from repro.apps import gbdt as G
from repro.apps import predicate as P
from repro.core.machine import PuDArch

ARCHS = [PuDArch.MODIFIED, PuDArch.UNMODIFIED]


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("method", ["clutch", "bitserial"])
@pytest.mark.parametrize("n_bits", [8, 16, 32])
def test_queries_match_reference(arch, method, n_bits):
    t = P.Table.generate(2000, n_bits, seed=5)
    mx = (1 << n_bits) - 1
    e = P.PudQueryEngine(t, arch, method)
    qa = dict(fi=0, x0=mx // 8, x1=mx // 2, fj=1, y0=mx // 4, y1=3 * mx // 4)
    assert (e.q1(0, mx // 8, mx // 2) ==
            P.reference_q1(t, 0, mx // 8, mx // 2)).all()
    assert (e.q2(**qa) == P.reference_q2(t, **qa)).all()
    assert e.q3(**qa) == P.reference_q3(t, **qa)
    assert abs(e.q4(fk=2, **qa) - P.reference_q4(t, 2, **qa)) < 1e-9
    assert e.q5(fl=3, fk=2, **qa) == P.reference_q5(t, 3, 2, **qa)


def test_clutch_fewer_ops_than_bitserial_per_query():
    t = P.Table.generate(1000, 32, seed=1)
    mx = (1 << 32) - 1
    counts = {}
    for method in ("clutch", "bitserial"):
        e = P.PudQueryEngine(t, PuDArch.MODIFIED, method)
        e.sub.trace.clear()
        e.q2(fi=0, x0=mx // 8, x1=mx // 2, fj=1, y0=mx // 4, y1=3 * mx // 4)
        counts[method] = e.sub.trace.pud_ops
    assert counts["clutch"] * 2 < counts["bitserial"]


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("n_bits", [8, 16])
def test_gbdt_exact_inference(arch, n_bits):
    forest = G.ObliviousForest.random(num_trees=50, depth=6,
                                      num_features=6, n_bits=n_bits, seed=2)
    rng = np.random.default_rng(11)
    x = rng.integers(0, 1 << n_bits, (12, 6), dtype=np.uint64)
    want_addr = G.reference_leaf_addrs(forest, x)
    want_pred = G.reference_predict(forest, x)
    eng = G.GbdtPudEngine(forest, arch)
    for i in range(x.shape[0]):
        addrs, pred = eng.infer_one(x[i])
        np.testing.assert_array_equal(addrs, want_addr[i])
        assert abs(pred - want_pred[i]) < 1e-3
    assert eng.ops_per_instance == G.gbdt_ops_per_instance(
        forest, eng.num_chunks, arch)


def test_gbdt_fit_learns():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 256, (600, 5), dtype=np.uint64)
    y = (x[:, 0].astype(float) > 128).astype(float) * 2 - 1 \
        + 0.5 * (x[:, 1].astype(float) / 255)
    f = G.fit_oblivious_forest(x, y, num_trees=40, depth=4, n_bits=8)
    pred = G.reference_predict(f, x)
    base = np.abs(y - y.mean()).mean()
    assert np.abs(y - pred).mean() < 0.6 * base


def test_gbdt_pud_runs_fitted_model():
    """End-to-end: fit -> load to PuD -> infer -> matches host inference."""
    rng = np.random.default_rng(4)
    x = rng.integers(0, 256, (300, 4), dtype=np.uint64)
    y = (x[:, 0].astype(float) - x[:, 2].astype(float)) / 128.0
    f = G.fit_oblivious_forest(x, y, num_trees=24, depth=5, n_bits=8)
    eng = G.GbdtPudEngine(f, PuDArch.UNMODIFIED)
    got = eng.infer(x[:8])
    want = G.reference_predict(f, x[:8])
    np.testing.assert_allclose(got, want, atol=1e-3)
