"""Async host/PuD pipeline accounting shared by the app engines.

Execution model: an app splits its work into *waves*.  For wave ``w`` it
records the PuD compute stream into one of two double-buffered result
rows, issues wave ``w+1``'s compute, and only then reads wave ``w``'s
buffer back and merges it on the host -- so host readout/merge of wave
``N`` overlaps PuD execution of wave ``N+1``.  The recorded stream
carries this structure as dependency-tagged segments (compute ``w``
depends on compute ``w-1`` and on the readout that freed its buffer;
readout ``w`` depends only on compute ``w``), which keeps the stream
functionally replayable and lets the per-channel bus scheduler place the
readout as early as its data allows.

This module turns a scheduled timeline + measured host-merge times into
the two totals the benchmarks report:

* ``serialized_ns``  -- every device wave back-to-back, every host merge
  after its wave: the no-pipeline baseline.
* ``overlapped_ns``  -- device waves at their scheduled times, host
  merge of wave ``w`` starting at max(readout ``w`` done, previous merge
  done): the double-buffered pipeline.

Device time is modeled (ns, from the scheduler); host time is the
measured wall-clock of the actual NumPy merge work, following the
paper's methodology of modeling the DRAM side and measuring the host
side.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.core.scheduler import Timeline


@dataclass
class PipelineStats:
    """Per-wave scheduled device spans + measured host merge times."""

    wave_done_ns: list[float] = field(default_factory=list)
    wave_busy_ns: list[float] = field(default_factory=list)
    host_ns: list[float] = field(default_factory=list)
    makespan_ns: float = 0.0     # device time of the pipeline's waves

    @property
    def num_waves(self) -> int:
        return len(self.wave_done_ns)

    @property
    def serialized_ns(self) -> float:
        """No-pipeline baseline: device waves back-to-back, each host
        merge completing before the next wave issues."""
        return sum(self.wave_busy_ns) + sum(self.host_ns)

    @property
    def overlapped_ns(self) -> float:
        """Double-buffered pipeline: merge of wave N overlaps device
        execution of wave N+1."""
        host_done = 0.0
        for done, host in zip(self.wave_done_ns, self.host_ns):
            host_done = max(done, host_done) + host
        return max(self.makespan_ns, host_done)

    @property
    def overlap_efficiency(self) -> float:
        """serialized / overlapped: >1 means the pipeline hides work."""
        ov = self.overlapped_ns
        return self.serialized_ns / ov if ov > 0 else 1.0


def stats_from_timeline(timeline: Timeline, group_labels: list[str],
                        wave_tags: list[list[str]],
                        host_ns: list[float]) -> PipelineStats:
    """Build :class:`PipelineStats` from a scheduled device timeline.

    ``wave_tags[w]`` lists the trace-segment labels belonging to wave
    ``w`` (its compute and readout segments) on every group in
    ``group_labels``.  Times are reported relative to the pipeline's
    first scheduled wave so one-time setup streams (LUT loading) in the
    same traces don't count against the pipeline.
    """
    groups = set(group_labels)
    tag_to_wave = {t: w for w, tags in enumerate(wave_tags)
                   for t in tags}
    done = [0.0] * len(wave_tags)
    busy = [0.0] * len(wave_tags)
    t0 = None
    t_end = 0.0
    for w in timeline.waves:
        if w.group not in groups or w.seg_label not in tag_to_wave:
            continue
        i = tag_to_wave[w.seg_label]
        busy[i] += w.duration_ns
        done[i] = max(done[i], w.end_ns)
        t0 = w.start_ns if t0 is None else min(t0, w.start_ns)
        t_end = max(t_end, w.end_ns)
    t0 = t0 or 0.0
    return PipelineStats(
        wave_done_ns=[max(0.0, d - t0) for d in done],
        wave_busy_ns=busy,
        host_ns=list(host_ns),
        makespan_ns=t_end - t0,
    )


class HostTimer:
    """Measures the host-side merge work of each pipeline wave."""

    def __init__(self) -> None:
        self.samples_ns: list[float] = []

    def measure(self, fn, *args, **kw):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        self.samples_ns.append((time.perf_counter() - t0) * 1e9)
        return out
