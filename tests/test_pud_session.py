"""Session API tests: free-map coalescing, planner edge cases
(admission-queue FIFO fairness, defrag relocation, eviction/reload),
multi-device federation, deprecation shims, and the serving front end.

Acceptance (ISSUE 4): all examples/benchmarks run through
``PudSession``; a 2-device federated Q1-Q5 run matches the NumPy
references bit-exactly; an alloc request exceeding free capacity is
*queued* and later admitted after ``free_banks`` -- demonstrated here,
not raised as an error.
"""

import numpy as np
import pytest

from repro.apps import gbdt as G
from repro.apps import predicate as P
from repro.core import cost
from repro.core.device import PuDDevice
from repro.core.machine import PuDArch, PuDOp
from repro.core.scheduler import federate_timelines
from repro.pud import Q1, Q2, Q3, Q4, Q5, PudSession
from repro.pud.executors import GbdtBatchExecutor, QueryBatchExecutor
from repro.serve.pud_service import PudRequest, PudService

MX = 255
QA = dict(fi=0, x0=MX // 8, x1=MX // 2, fj=1, y0=MX // 4, y1=3 * MX // 4)


def small_device(banks=8, channels=1):
    """One-channel-ish device where bank counts are easy to reason
    about: cols_per_bank=4096 => one bank per 4096 records."""
    return PuDDevice(PuDArch.MODIFIED, channels=channels,
                     ranks_per_channel=1, banks_per_rank=banks // channels,
                     num_rows=1024, cols_per_bank=4096)


def small_session(banks=8, channels=1):
    return PudSession(sys_cfg=cost.DESKTOP,
                      devices=[small_device(banks, channels)])


def records(n_banks):
    return 4096 * n_banks


def table(n_banks, seed=0):
    return P.Table.generate(records(n_banks), 8, seed=seed)


# --------------------------------------------------------------------- #
# Free-map coalescing (satellite: unit-test the coalescing)
# --------------------------------------------------------------------- #

def test_free_banks_coalesces_adjacent_ranges():
    dev = small_device(banks=8)
    a = dev.alloc_banks(2, label="a")
    b = dev.alloc_banks(2, label="b")
    c = dev.alloc_banks(2, label="c")
    assert dev.free_ranges == ((6, 2),)
    dev.free_banks(a)
    assert dev.free_ranges == ((0, 2), (6, 2))
    dev.free_banks(c)          # adjacent to the tail range -> one run
    assert dev.free_ranges == ((0, 2), (4, 4))
    dev.free_banks(b)          # bridges both neighbors -> fully merged
    assert dev.free_ranges == ((0, 8),)


def test_alloc_free_realloc_larger_group_succeeds():
    """alloc -> free -> realloc of a LARGER contiguous group: the freed
    ranges coalesce, so the bigger run is found despite the free map
    having been split."""
    dev = small_device(banks=8)
    a = dev.alloc_banks(3, label="a")
    b = dev.alloc_banks(3, label="b")
    dev.free_banks(a)
    dev.free_banks(b)
    sub = dev.alloc_banks(6, label="bigger")   # > either freed group
    assert dev.groups[-1].banks == tuple(range(6))
    assert sub.num_banks == 6


def test_spread_group_frees_as_separate_runs():
    dev = small_device(banks=8, channels=2)
    s = dev.alloc_banks(4, label="s", channels="spread")
    assert {dev.address(b).channel for b in dev.groups[-1].banks} == {0, 1}
    dev.free_banks(s)
    assert dev.free_ranges == ((0, 8),)


def test_failed_multichannel_placement_leaks_nothing():
    dev = small_device(banks=8, channels=2)
    dev.alloc_banks(3, label="hog", channels=1)
    with pytest.raises(MemoryError):
        dev.alloc_banks(4, num_cols=4096, label="x", channels="spread")
    assert dev.banks_free == 5     # the channel-0 half was not carved


# --------------------------------------------------------------------- #
# Defragmentation
# --------------------------------------------------------------------- #

def test_defragment_compacts_and_records_relocation_cost():
    dev = small_device(banks=8)
    a = dev.alloc_banks(2, label="a")
    b = dev.alloc_banks(2, label="b")
    c = dev.alloc_banks(2, label="c")
    d = dev.alloc_banks(2, label="d")
    dev.free_banks(a)
    dev.free_banks(c)
    assert dev.largest_free_run == 2 and dev.banks_free == 4
    moved = dev.defragment()
    assert moved == 4          # b slid to 0..1, d slid to 2..3
    assert dev.largest_free_run == dev.banks_free == 4
    assert dev.groups[0].banks == (0, 1)
    assert dev.groups[1].banks == (2, 3)
    assert d is not None
    # relocation is in-DRAM by default: RowClone/MRACT waves, no host
    # round trip over the pins
    clones = sum(1 for e in b.trace.entries
                 if e.op in (PuDOp.ROWCLONE, PuDOp.MRACT))
    hostio = sum(1 for e in b.trace.entries
                 if e.op in (PuDOp.READ, PuDOp.WRITE))
    assert clones >= 1 and hostio == 0
    assert any(s.label.startswith("defrag:") for s in b.trace.segments)


def test_defrag_relocation_preserves_query_state_bit_exactly():
    """Planner defrag path: a fragmented free map blocks a contiguous
    placement; the planner relocates resident groups to close the hole
    and the relocated table keeps answering queries bit-exactly."""
    s = small_session(banks=8)
    ta = s.create_table(table(2, seed=1), name="a", shards_per_device=1)
    tb = s.create_table(table(2, seed=2), name="b", shards_per_device=1)
    s.create_table(table(2, seed=3), name="c", shards_per_device=1)
    q = Q2(**QA)
    before = s.query(tb, q).result
    s.drop(ta)                  # free map: [0,2) + [6,8) -- fragmented
    td = s.create_table(table(3, seed=4), name="d", shards_per_device=1)
    assert td.status == "ready"             # needed defrag to fit
    assert s.planner.defrag_banks_moved > 0
    assert s.planner_stats()["resources"] == {
        "b": "ready", "c": "ready", "d": "ready"}
    after = s.query(tb, q).result
    assert (before == after).all()
    assert (after == q.reference(
        s.planner.resources["b"].executor.table)).all()


# --------------------------------------------------------------------- #
# Admission queue (acceptance: queued, then admitted after free)
# --------------------------------------------------------------------- #

def test_oversubscribed_alloc_is_queued_then_admitted_after_free():
    s = small_session(banks=8)
    ta = s.create_table(table(3, seed=1), name="a",
                        shards_per_device=1, pinned=True)
    s.create_table(table(3, seed=2), name="b",
                   shards_per_device=1, pinned=True)
    big = s.create_table(table(4, seed=3), name="big",
                         shards_per_device=1)
    assert big.status == "queued"           # a queue state, NOT an error
    with pytest.raises(RuntimeError, match="queued"):
        s.query(big, Q1(**{k: QA[k] for k in ("fi", "x0", "x1")}))
    s.drop(ta)                              # free_banks -> queue drains
    assert big.status == "ready"
    q = Q3(**QA)
    got = s.query(big, q).result
    assert got == q.reference(s.planner.resources["big"].executor.table)


def test_admission_queue_is_fifo_no_queue_jumping():
    s = small_session(banks=8)
    s.create_table(table(5, seed=1), name="a", shards_per_device=1,
                   pinned=True)
    tb = s.create_table(table(2, seed=2), name="b", shards_per_device=1,
                        pinned=True)
    big = s.create_table(table(2, seed=3), name="big",
                         shards_per_device=1)     # 1 free -> queued
    small = s.create_table(table(1, seed=4), name="small",
                           shards_per_device=1)
    # `small` WOULD fit in the one free bank, but the queue is strict
    # FIFO: it must wait behind `big` (no starvation of large requests).
    assert big.status == "queued" and small.status == "queued"
    assert s.planner.queued_names() == ["big", "small"]
    s.drop(tb)                               # 3 free -> drain in order
    assert big.status == "ready" and small.status == "ready"
    assert s.planner.queued_names() == []


def test_impossible_request_does_not_strip_resident_resources():
    """A request larger than the whole device parks in the queue
    WITHOUT permanently evicting residents: the failed escalation
    rebuilds its victims, and later releases don't re-churn the fleet
    for a request that still cannot fit."""
    s = small_session(banks=8)
    ta = s.create_table(table(2, seed=1), name="a", shards_per_device=1)
    tc = s.create_table(table(2, seed=2), name="c", shards_per_device=1)
    big = s.create_table(table(16, seed=3), name="big",
                         shards_per_device=1)     # 16 banks > 8 total
    assert big.status == "queued"
    assert ta.status == "ready" and tc.status == "ready"   # rolled back
    q = Q1(fi=0, x0=10, x1=200)
    ref = s.query(ta, q).result
    s.drop(tc)          # drain retries are gated on capacity growth:
    evictions_before = s.planner.evictions
    assert big.status == "queued"
    assert ta.status == "ready"
    assert s.planner.evictions == evictions_before
    assert (s.query(ta, q).result == ref).all()


def test_eviction_retries_defrag_for_fragmented_free_space():
    """Evicting a victim may leave non-adjacent free runs; the planner
    must re-defragment after the eviction so a placement that fits the
    *total* freed capacity is admitted, not queued."""
    s = small_session(banks=8)
    ta = s.create_table(table(3, seed=1), name="a", shards_per_device=1)
    s.create_table(table(2, seed=2), name="p", shards_per_device=1,
                   pinned=True)
    # free: [5,8) = 3 banks; R needs 5 contiguous. Evicting `a` frees
    # [0,3), still fragmented around pinned `p` -- only defrag-after-
    # evict (slide p down) yields a 6-bank run.
    tr = s.create_table(table(5, seed=3), name="r", shards_per_device=1)
    assert tr.status == "ready"
    assert ta.status == "evicted"
    assert s.planner.defrag_banks_moved > 0
    q = Q1(fi=0, x0=10, x1=200)
    got = s.query(tr, q).result
    assert (got == q.reference(
        s.planner.resources["r"].executor.table)).all()


def test_partial_build_rolls_back_cleanly():
    """A build whose second shard overflows must free the first shard's
    banks (atomic admission -- no leak while queued)."""
    s = small_session(banks=6)
    free0 = s.devices[0].banks_free
    h = s.create_table(table(8, seed=5), name="x", shards_per_device=2)
    assert h.status == "queued"
    assert s.devices[0].banks_free == free0


# --------------------------------------------------------------------- #
# Eviction / reload
# --------------------------------------------------------------------- #

def test_eviction_and_reload_round_trip():
    s = small_session(banks=8)
    ta = s.create_table(table(4, seed=1), name="a", shards_per_device=1)
    tb = s.create_table(table(4, seed=2), name="b", shards_per_device=1)
    q = Q2(**QA)
    ref_a = s.query(ta, q).result
    s.query(tb, q)                          # b is now hotter than a
    tc = s.create_table(table(4, seed=3), name="c", shards_per_device=1)
    # no free banks: the planner must evict the LRU table (a) to admit c
    assert tc.status == "ready"
    assert ta.status == "evicted"
    assert s.planner.evictions >= 1
    # touching the evicted table reloads it from host data (evicting
    # the now-coldest resource) and answers bit-exactly
    got = s.query(ta, q).result
    assert ta.status == "ready"
    assert (got == ref_a).all()
    assert s.planner.resources["a"].builds == 2


def test_pinned_resources_are_never_evicted():
    s = small_session(banks=8)
    s.create_table(table(4, seed=1), name="a", shards_per_device=1,
                   pinned=True)
    s.create_table(table(4, seed=2), name="b", shards_per_device=1,
                   pinned=True)
    tc = s.create_table(table(4, seed=3), name="c", shards_per_device=1)
    assert tc.status == "queued"
    assert s.planner_stats()["resources"]["a"] == "ready"
    assert s.planner_stats()["resources"]["b"] == "ready"


# --------------------------------------------------------------------- #
# Multi-device federation
# --------------------------------------------------------------------- #

def test_federated_q1_q5_match_references_1m_records():
    """Acceptance: Q1-Q5 over a 1M-record table sharded across TWO
    devices match the single-table NumPy references bit-exactly
    (including Q5's cross-device host-barrier round trip)."""
    t = P.Table.generate(1_000_000, 8, seed=11)
    s = PudSession(sys_cfg=cost.DESKTOP, num_devices=2)
    h = s.create_table(t, name="t")
    qs = [Q1(fi=0, x0=MX // 8, x1=MX // 2), Q2(**QA), Q3(**QA),
          Q4(fk=2, **QA), Q5(fl=3, fk=2, **QA)]
    job = s.query(h, qs)
    assert (job.result[0] == qs[0].reference(t)).all()
    assert (job.result[1] == qs[1].reference(t)).all()
    assert job.result[2] == qs[2].reference(t)
    assert abs(job.result[3] - qs[3].reference(t)) < 1e-9
    assert job.result[4] == qs[4].reference(t)
    # stats ride the federated barrier-aware timeline
    assert job.stats.num_waves == 6      # five queries + Q5 phase 2
    assert job.stats.overlapped_ns <= job.stats.serialized_ns + 1e-6
    # shards really landed on both devices
    assert all(d.groups for d in s.devices)


def test_federated_gbdt_predict_matches_reference():
    forest = G.ObliviousForest.random(num_trees=16, depth=4,
                                      num_features=4, n_bits=8, seed=3)
    s = PudSession(sys_cfg=cost.DESKTOP, num_devices=2)
    h = s.load_forest(forest, name="f", groups_per_device=2,
                      banks_per_group=2)
    rng = np.random.default_rng(9)
    X = rng.integers(0, 256, (13, 4), dtype=np.uint64)
    job = s.predict(h, X)
    np.testing.assert_allclose(job.result, G.reference_predict(forest, X),
                               atol=1e-3)
    assert job.stats.overlapped_ns <= job.stats.serialized_ns + 1e-6
    assert all(d.groups for d in s.devices)


def test_federated_timeline_rekeys_channels_and_unifies_host_merges():
    t = table(2, seed=6)
    s = PudSession(sys_cfg=cost.DESKTOP, num_devices=2)
    h = s.create_table(t, name="t", cols_per_bank=4096)
    s.query(h, [Q1(fi=0, x0=10, x1=200), Q3(**QA)])
    per_dev = [d.schedule(s.sys_cfg) for d in s.devices]
    fed = federate_timelines(per_dev)
    # channels from different devices never collide
    assert len(fed.channel_busy_ns) == sum(
        len(tl.channel_busy_ns) for tl in per_dev)
    assert fed.makespan_ns >= max(tl.makespan_ns for tl in per_dev)
    # a shared merge label scheduled on both devices is ONE host node
    labels = [hs.label for hs in fed.host_spans]
    assert len(labels) == len(set(labels))
    per_dev_labels = [hs.label for tl in per_dev for hs in tl.host_spans]
    assert len(per_dev_labels) > len(set(per_dev_labels))
    # the serving-layer merge node extends the makespan
    fed2 = federate_timelines(per_dev, merge_ns=123.0)
    assert fed2.makespan_ns == pytest.approx(fed.makespan_ns + 123.0)
    assert fed2.host_spans[-1].label == "federate:merge"


def test_cross_device_host_barrier_holds_on_asymmetric_fleet():
    """A Q5 phase-1 merge consumes EVERY device's readouts, so no
    device's phase-2 wave may be scheduled before the fleet-wide merge
    node ends -- even when one device is much faster (more channels)
    than the other.  Joint fleet scheduling guarantees this; post-hoc
    per-device federation did not."""
    fast = PuDDevice(PuDArch.MODIFIED, channels=4, ranks_per_channel=2,
                     banks_per_rank=16, cols_per_bank=4096)
    slow = PuDDevice(PuDArch.MODIFIED, channels=1, ranks_per_channel=1,
                     banks_per_rank=16, cols_per_bank=4096)
    s = PudSession(sys_cfg=cost.DESKTOP, devices=[fast, slow])
    t = table(8, seed=12)
    h = s.create_table(t, name="t", cols_per_bank=4096)
    q = Q5(fl=3, fk=2, **QA)
    job = s.query(h, q)
    assert job.result == q.reference(t)
    tl = job.timeline
    merge = [hs for hs in tl.host_spans if hs.label.endswith("w0:h")]
    assert len(merge) == 1              # one fleet-wide host node
    p2 = [w for w in tl.waves if w.seg_label.endswith("w1:c")]
    assert p2
    assert min(w.start_ns for w in p2) >= merge[0].end_ns - 1e-6
    assert job.stats.overlapped_ns <= job.stats.serialized_ns + 1e-6


def test_job_timelines_are_job_scoped_not_cumulative():
    """Every job's timeline covers exactly that job: no LUT-load waves,
    and a repeat of the same query costs the same -- not the session's
    accumulated history."""
    s = small_session(banks=8)
    h = s.create_table(table(2, seed=1), name="t", shards_per_device=1)
    q = Q1(fi=0, x0=10, x1=200)
    j1 = s.query(h, q)
    j2 = s.query(h, q)
    assert all(w.op is not PuDOp.WRITE for w in j1.timeline.waves)
    assert len(j1.timeline.waves) == len(j2.timeline.waves)
    assert j1.timeline.device_span_ns == pytest.approx(
        j2.timeline.device_span_ns)


# --------------------------------------------------------------------- #
# Direct executor construction (the PR-4 deprecation shims are gone)
# --------------------------------------------------------------------- #

def test_pipeline_shims_removed():
    assert not hasattr(P, "ShardedQueryPipeline")
    assert not hasattr(G, "GbdtBatchPipeline")


def test_query_executor_direct_construction():
    t = table(1, seed=7)
    dev = PuDDevice.from_system(cost.DESKTOP, PuDArch.MODIFIED)
    qp = QueryBatchExecutor(t, PuDArch.MODIFIED, [dev],
                            shards_per_device=2, cols_per_bank=4096)
    res = qp.run([("q1", 0, 10, 200)])
    assert (res[0] == P.reference_q1(t, 0, 10, 200)).all()


def test_gbdt_executor_direct_construction():
    forest = G.ObliviousForest.random(num_trees=8, depth=3,
                                      num_features=3, n_bits=8, seed=2)
    dev = PuDDevice.from_system(cost.DESKTOP, PuDArch.MODIFIED)
    pipe = GbdtBatchExecutor(forest, PuDArch.MODIFIED, [dev],
                             groups_per_device=2, banks_per_group=2)
    rng = np.random.default_rng(4)
    X = rng.integers(0, 256, (5, 3), dtype=np.uint64)
    np.testing.assert_allclose(pipe.infer(X),
                               G.reference_predict(forest, X), atol=1e-3)


# --------------------------------------------------------------------- #
# Serving front end
# --------------------------------------------------------------------- #

def test_pud_service_batches_per_resource_with_per_request_stats():
    t = table(2, seed=8)
    svc = PudService(PudSession(sys_cfg=cost.DESKTOP, num_devices=2))
    svc.session.create_table(t, name="events", cols_per_bank=4096)
    forest = G.ObliviousForest.random(num_trees=8, depth=3,
                                      num_features=3, n_bits=8, seed=5)
    svc.session.load_forest(forest, name="ranker", banks_per_group=2)
    rng = np.random.default_rng(6)
    X1 = rng.integers(0, 256, (3, 3), dtype=np.uint64)
    X2 = rng.integers(0, 256, (4, 3), dtype=np.uint64)
    svc.submit(PudRequest(rid=1, resource="events",
                          query=Q1(fi=0, x0=10, x1=200)))
    svc.submit(PudRequest(rid=2, resource="ranker", X=X1))
    svc.submit(PudRequest(rid=3, resource="events", query=Q3(**QA)))
    svc.submit(PudRequest(rid=4, resource="ranker", X=X2))
    assert svc.queue_depth == 4
    rs = svc.flush()
    assert svc.queue_depth == 0
    assert [r.rid for r in rs] == [1, 2, 3, 4]
    assert (rs[0].result == P.reference_q1(t, 0, 10, 200)).all()
    assert rs[2].result == P.reference_q3(t, **QA)
    np.testing.assert_allclose(
        np.concatenate([rs[1].result, rs[3].result]),
        G.reference_predict(forest, np.concatenate([X1, X2])), atol=1e-3)
    # query requests batched together: shared stats, per-wave latency
    assert rs[0].batch_size == rs[2].batch_size == 2
    assert rs[0].stats is rs[2].stats
    assert 0 < rs[0].latency_ns <= rs[2].latency_ns
    # predict requests share one inference batch
    assert rs[1].batch_size == rs[3].batch_size == 2
    assert rs[1].stats is rs[3].stats


def test_pud_service_rejects_duplicate_rids():
    svc = PudService(PudSession(sys_cfg=cost.DESKTOP))
    svc.session.create_table(table(1, seed=9), name="t",
                             cols_per_bank=4096)
    svc.submit(PudRequest(rid=1, resource="t", query=Q1(fi=0, x0=1, x1=9)))
    with pytest.raises(ValueError, match="duplicate"):
        svc.submit(PudRequest(rid=1, resource="t",
                              query=Q1(fi=0, x0=2, x1=8)))


def test_pud_service_rejects_mismatched_requests():
    svc = PudService(PudSession(sys_cfg=cost.DESKTOP))
    svc.session.create_table(table(1, seed=9), name="t",
                             cols_per_bank=4096)
    with pytest.raises(ValueError):
        PudRequest(rid=1, resource="t")
    with pytest.raises(TypeError):
        svc.submit(PudRequest(rid=2, resource="t",
                              X=np.zeros((1, 3), np.uint64)))
        svc.flush()
    svc._pending.clear()
    with pytest.raises(KeyError):
        svc.submit(PudRequest(rid=3, resource="nope",
                               query=Q1(fi=0, x0=1, x1=2)))
        svc.flush()


# --------------------------------------------------------------------- #
# Session plumbing
# --------------------------------------------------------------------- #

def test_broken_build_queued_behind_capacity_fails_cleanly_on_drain():
    """A broken recipe admitted while the queue is non-empty is only
    attempted at drain time: the error must not raise out of drop(),
    must not wedge the queue, and the name must be recoverable."""
    s = small_session(banks=8)
    ta = s.create_table(table(3, seed=1), name="a", shards_per_device=1,
                        pinned=True)
    s.create_table(table(3, seed=2), name="b", shards_per_device=1,
                   pinned=True)
    big = s.create_table(table(4, seed=3), name="big",
                         shards_per_device=1)        # queued (capacity)
    bad = s.create_table(table(1, seed=4), name="bad", method="bogus")
    ok = s.create_table(table(1, seed=5), name="ok", shards_per_device=1)
    assert bad.status == "queued" and ok.status == "queued"
    s.drop(ta)          # drain: big admitted, bad fails, ok admitted
    assert big.status == "ready"
    assert bad.status == "failed"
    assert ok.status == "ready"
    with pytest.raises(RuntimeError, match="failed to build"):
        s.query(bad, Q1(fi=0, x0=1, x1=9))
    s.drop(bad)         # failed resources drop cleanly; name reusable
    h = s.create_table(table(1, seed=4), name="bad", shards_per_device=1)
    assert h.status == "ready"


def test_empty_predict_batch_reports_empty_job_timeline():
    forest = G.ObliviousForest.random(num_trees=8, depth=3,
                                      num_features=3, n_bits=8, seed=2)
    s = small_session(banks=8)
    h = s.load_forest(forest, name="f", groups_per_device=1,
                      banks_per_group=1)
    rng = np.random.default_rng(4)
    s.predict(h, rng.integers(0, 256, (3, 3), dtype=np.uint64))
    job = s.predict(h, np.empty((0, 3), np.uint64))
    assert job.result.shape == (0,)
    assert job.timeline.waves == []     # not the previous job's
    assert job.stats.makespan_ns == 0.0


def test_query_check_helper_matches_and_rejects():
    t = table(1, seed=13)
    s = small_session(banks=8)
    h = s.create_table(t, name="t", shards_per_device=1)
    qs = [Q1(fi=0, x0=10, x1=200), Q4(fk=2, **QA)]
    job = s.query(h, qs)
    assert all(q.check(t, got) for q, got in zip(qs, job.result))
    assert not qs[0].check(t, ~job.result[0])
    assert not qs[1].check(t, job.result[1] + 1.0)


def test_broken_build_recipe_does_not_poison_the_name():
    """A build that raises a non-capacity error (bad method name) must
    propagate, leak no banks, and leave the name reusable."""
    s = small_session(banks=8)
    free0 = s.devices[0].banks_free
    with pytest.raises(ValueError, match="bogus"):
        s.create_table(table(1, seed=1), name="t", method="bogus")
    assert "t" not in s.planner.resources
    assert s.devices[0].banks_free == free0
    h = s.create_table(table(1, seed=1), name="t", shards_per_device=1)
    assert h.status == "ready"


def test_handle_status_after_drop_is_dropped():
    s = small_session(banks=8)
    h = s.create_table(table(1, seed=1), name="t", shards_per_device=1)
    s.drop(h)
    assert h.status == "dropped"
    with pytest.raises(KeyError, match="already dropped"):
        s.drop(h)


def test_failed_flush_preserves_pending_requests():
    """One bad request must not destroy the batch: flush fails before
    executing anything, the queue survives, and cancelling the bad
    request lets the rest flush normally."""
    t = table(1, seed=9)
    svc = PudService(PudSession(sys_cfg=cost.DESKTOP))
    svc.session.create_table(t, name="good", cols_per_bank=4096)
    svc.submit(PudRequest(rid=1, resource="good",
                          query=Q1(fi=0, x0=10, x1=200)))
    svc.submit(PudRequest(rid=2, resource="missing",
                          query=Q1(fi=0, x0=10, x1=200)))
    with pytest.raises(KeyError):
        svc.flush()
    assert svc.queue_depth == 2          # nothing was lost
    assert svc.cancel(2) and not svc.cancel(99)
    rs = svc.flush()
    assert [r.rid for r in rs] == [1]
    assert (rs[0].result == P.reference_q1(t, 0, 10, 200)).all()
    assert svc.queue_depth == 0


def test_session_rejects_mixed_arch_devices_and_wrong_kinds():
    with pytest.raises(ValueError, match="arch"):
        PudSession(devices=[
            PuDDevice(PuDArch.MODIFIED, channels=1, ranks_per_channel=1,
                      banks_per_rank=4),
            PuDDevice(PuDArch.UNMODIFIED, channels=1, ranks_per_channel=1,
                      banks_per_rank=4)])
    s = small_session()
    h = s.create_table(table(1), name="t")
    with pytest.raises(TypeError, match="table"):
        s.predict(h, np.zeros((1, 8), np.uint64))
    s.drop(h)
    with pytest.raises(KeyError):
        s.query(h, Q1(fi=0, x0=1, x1=2))


def test_session_raw_array_table_and_cost_summary():
    rng = np.random.default_rng(3)
    arr = rng.integers(0, 256, (5000, 3), dtype=np.uint64)
    s = small_session()
    with pytest.raises(ValueError, match="n_bits"):
        s.create_table(arr, name="x")
    h = s.create_table(arr, n_bits=8, name="x", shards_per_device=1)
    q = Q1(fi=2, x0=17, x1=200)
    f = arr[:, 2]
    assert (s.query(h, q).result == ((f > 17) & (f < 200))).all()
    cs = s.cost_summary()
    assert cs["time_scheduled_ns"] > 0
    assert len(cs["devices"]) == 1
    assert cs["energy_nj"] > 0
