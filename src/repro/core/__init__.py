"""Clutch core: PuD machine model, chunked temporal coding, Algorithm 1,
bit-serial baseline, and the analytical DRAM cost model."""

from .machine import (  # noqa: F401
    CommandTrace,
    PuDArch,
    PuDOp,
    Subarray,
    pack_bits,
    unpack_bits,
)
from .encoding import (  # noqa: F401
    ChunkPlan,
    ColumnPlan,
    LutLayout,
    column_footprint_rows,
    infer_n_bits,
    load_binary_vector,
    load_vector,
    make_plan,
    min_chunks_for_budget,
    temporal_encode_planes,
)
from .clutch import ClutchEngine, clutch_op_count, compare_lt  # noqa: F401
from .bitserial import (  # noqa: F401
    BitSerialEngine,
    bitserial_op_count,
    paper_bitserial_op_count,
)
from . import cost  # noqa: F401
