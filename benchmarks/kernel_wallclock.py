"""Measured wall-clock of the TPU-kernel implementations (interpret mode
on CPU -- relative numbers only; the roofline section covers the TPU
target).  Also times the functional PuD machine simulator."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoding import make_plan
from repro.kernels import ops


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)
    n = 1 << 18
    for n_bits, chunks in [(8, 1), (16, 2), (32, 5)]:
        plan = make_plan(n_bits, chunks)
        vals = jnp.asarray(rng.integers(0, 1 << n_bits, n, dtype=np.uint32))
        lut = ops.encode_lut(vals, plan)
        lt, le = ops.resolve_indices(plan, 1 << (n_bits - 1))
        us = _time(ops.compare_gt_scalar, lut, jnp.asarray(lt),
                   jnp.asarray(le))
        rows.append((f"kernel_clutch_merge_{n_bits}b", round(us, 1),
                     round(n / us, 1)))  # elems/us
        planes = ops.encode_bitplanes(vals, n_bits)
        us = _time(lambda p: ops.bitserial_compare(p, 12345, n_bits),
                   planes)
        rows.append((f"kernel_bitserial_{n_bits}b", round(us, 1),
                     round(n / us, 1)))
    logits = jnp.asarray(rng.normal(size=(8, 32768)).astype(np.float32))
    tau = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    us = _time(ops.sample_threshold_mask, logits, tau)
    rows.append(("kernel_minp_mask_8x32k", round(us, 1),
                 round(8 * 32768 / us, 1)))
    addrs = jnp.asarray(rng.integers(0, 1 << 10, (256, 512), dtype=np.int32))
    leaves = jnp.asarray(rng.normal(size=(512, 1 << 10)).astype(np.float32))
    us = _time(ops.gbdt_leaf_sum, addrs, leaves)
    rows.append(("kernel_leaf_gather_256x512", round(us, 1),
                 round(256 * 512 / us, 1)))
    return rows
