"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state -- required because the dry-run must
set XLA_FLAGS before any JAX initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod (v5e); the multi-pod mesh adds a leading
    2-pod axis used for data parallelism (and optionally pipeline stages)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this process actually has -- used by smoke tests/examples."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
