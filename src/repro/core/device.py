"""PuD device hierarchy: channels x ranks x banks owning bank allocation.

The machine layer (:mod:`repro.core.machine`) models *one bank group* --
a set of banks executing a broadcast command stream.  This module adds the
device above it:

  * :class:`PuDDevice` mirrors a :class:`~repro.core.cost.SystemConfig`'s
    channel/rank/bank topology and hands out :class:`BankGroup` slices of
    it.  Allocation is a bump pointer over the flat bank index space;
    banks are addressed ``(channel, rank, bank)`` in row-major order, so a
    contiguous group spans whole ranks before spilling to the next channel
    (matching how the BLP cost model staggers ACTs per rank).
  * Engine-to-bank placement: apps allocate their
    :class:`~repro.core.machine.BankedSubarray` through the device
    (``alloc_banks``), which records the placement so ``cost_summary`` can
    turn every group's real command trace into device-level latency and
    energy via the analytical model.

Trace semantics: each group keeps its own :class:`CommandTrace`; one entry
is one broadcast wave across that group's banks.  Groups on disjoint banks
could overlap in time on real hardware -- ``cost_summary`` reports both
the serialized sum and the max (perfectly-overlapped lower bound) so
benchmarks can show the achievable range.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .machine import BankedSubarray, PuDArch


@dataclass(frozen=True)
class BankAddress:
    channel: int
    rank: int
    bank: int


@dataclass
class BankGroup:
    """A placed engine: which flat banks it owns and its machine state."""

    first_bank: int
    sub: BankedSubarray
    label: str = ""

    @property
    def num_banks(self) -> int:
        return self.sub.num_banks


class PuDDevice:
    """A whole PuD-enabled memory device (channels x ranks x banks)."""

    def __init__(
        self,
        arch: PuDArch,
        channels: int = 2,
        ranks_per_channel: int = 2,
        banks_per_rank: int = 16,
        num_rows: int = 1024,
        cols_per_bank: int = 65536,
        seed: int | None = 0,
    ) -> None:
        self.arch = arch
        self.channels = channels
        self.ranks_per_channel = ranks_per_channel
        self.banks_per_rank = banks_per_rank
        self.num_rows = num_rows
        self.cols_per_bank = cols_per_bank
        self._seed = seed
        self._next_bank = 0
        self.groups: list[BankGroup] = []

    @classmethod
    def from_system(cls, sys_cfg, arch: PuDArch,
                    num_rows: int = 1024) -> "PuDDevice":
        """Build a device matching a cost-model SystemConfig topology."""
        return cls(arch, channels=sys_cfg.channels,
                   ranks_per_channel=sys_cfg.ranks_per_channel,
                   banks_per_rank=sys_cfg.banks_per_rank,
                   num_rows=num_rows, cols_per_bank=sys_cfg.cols_per_bank)

    # ------------------------------------------------------------------ #
    @property
    def total_banks(self) -> int:
        return self.channels * self.ranks_per_channel * self.banks_per_rank

    @property
    def banks_free(self) -> int:
        return self.total_banks - self._next_bank

    @property
    def parallel_cols(self) -> int:
        """Device SIMD width when every bank computes."""
        return self.total_banks * self.cols_per_bank

    def address(self, flat_bank: int) -> BankAddress:
        """(channel, rank, bank) of a flat bank index."""
        if not 0 <= flat_bank < self.total_banks:
            raise IndexError(flat_bank)
        per_ch = self.ranks_per_channel * self.banks_per_rank
        return BankAddress(
            channel=flat_bank // per_ch,
            rank=(flat_bank % per_ch) // self.banks_per_rank,
            bank=flat_bank % self.banks_per_rank,
        )

    # ------------------------------------------------------------------ #
    def alloc_banks(self, n: int, num_cols: int | None = None,
                    label: str = "") -> BankedSubarray:
        """Allocate ``n`` consecutive banks as one broadcast group and
        return its machine state.  Raises MemoryError when the device is
        out of banks (callers shard or queue waves above this layer)."""
        if n < 1:
            raise ValueError("need at least one bank")
        if self._next_bank + n > self.total_banks:
            raise MemoryError(
                f"device bank budget exceeded: need {n} banks at "
                f"{self._next_bank}, capacity {self.total_banks}")
        sub = BankedSubarray(
            num_banks=n, num_rows=self.num_rows,
            num_cols=num_cols or self.cols_per_bank, arch=self.arch,
            seed=None if self._seed is None
            else self._seed + self._next_bank)
        group = BankGroup(first_bank=self._next_bank, sub=sub, label=label)
        self._next_bank += n
        self.groups.append(group)
        return sub

    # ------------------------------------------------------------------ #
    def cost_summary(self, sys_cfg) -> dict:
        """Run every group's recorded trace through the analytical BLP
        cost model.  Returns per-group and device-level time/energy:
        ``time_serial_ns`` assumes groups execute back-to-back (shared
        command bus), ``time_overlap_ns`` is the perfectly-overlapped
        lower bound (disjoint banks, independent channels)."""
        from . import cost

        per_group = []
        for g in self.groups:
            kc = cost.trace_cost(g.sub.trace.counts(), sys_cfg,
                                 banks=g.num_banks,
                                 cols_per_bank=g.sub.num_cols)
            per_group.append({
                "label": g.label or f"banks[{g.first_bank}:"
                                    f"{g.first_bank + g.num_banks}]",
                "banks": g.num_banks,
                "pud_ops": g.sub.trace.pud_ops,
                "time_ns": kc.time_ns,
                "energy_nj": kc.energy_nj,
            })
        return {
            "groups": per_group,
            "banks_used": self._next_bank,
            "time_serial_ns": sum(g["time_ns"] for g in per_group),
            "time_overlap_ns": max(
                (g["time_ns"] for g in per_group), default=0.0),
            "energy_nj": sum(g["energy_nj"] for g in per_group),
        }
